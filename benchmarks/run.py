"""Benchmark harness: one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,roofline]

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import time

ALL = [
    "fig2_interleave",
    "fig9_poisson",
    "fig10_dynamic",
    "fig11_modelpar",
    "table2_snapshots",
    "fig13_multigpu",
    "fig15_discretization",
    "ablations",
    "kernels",
    "roofline",
]


def _kernel_bench() -> list[dict]:
    """Micro-bench the three Pallas kernels (interpret mode) vs oracles."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.circle_score.ops import circle_score
    from repro.kernels.circle_score.ref import circle_score_ref
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssd_scan.ops import ssd_scan

    from .common import timed

    rng = np.random.default_rng(0)
    rows = []
    base = jnp.asarray(rng.random((16, 720)) * 60, jnp.float32)
    cand = jnp.asarray(rng.random((16, 720)) * 60, jnp.float32)
    _, us_ref = timed(lambda: circle_score_ref(base, cand, 50.0).block_until_ready())
    _, us_k = timed(lambda: circle_score(base, cand, 50.0).block_until_ready())
    rows.append({"name": "kernels/circle_score(16x720)", "us_per_call": us_k,
                 "derived": f"jnp_ref={us_ref:.0f}us (interpret-mode kernel; "
                            f"TPU target compiles Mosaic)"})
    q = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.bfloat16)
    _, us_fa = timed(lambda: flash_attention(q, k, v).block_until_ready(), repeat=1)
    rows.append({"name": "kernels/flash_attention(512)", "us_per_call": us_fa,
                 "derived": "blocked online-softmax; causal GQA"})
    x = jnp.asarray(rng.standard_normal((1, 256, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.random((1, 256, 4)) * 0.3 + 0.05, jnp.float32)
    al = jnp.asarray(rng.standard_normal(4) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((1, 256, 16)), jnp.float32)
    _, us_ssd = timed(lambda: ssd_scan(x, dt, al, Bm, Cm, chunk=64).block_until_ready(),
                      repeat=1)
    rows.append({"name": "kernels/ssd_scan(256)", "us_per_call": us_ssd,
                 "derived": "chunked SSD w/ VMEM state carry"})
    rows.extend(_batched_scoring_bench())
    return rows


def _batched_scoring_bench() -> list[dict]:
    """Batched candidate scoring (``find_rotations_batched``) vs the scalar
    per-link loop the seed scheduler ran — the Algorithm-2 hot path.

    Doubles as the CI smoke check for the batched paths: every
    configuration asserts (via ``BatchStats``) that no problem silently
    fell back to the scalar search, and the k=3 grid configuration asserts
    a >1x measured speedup over the scalar loop.
    """
    from repro.core.compat import BatchStats, find_rotations, find_rotations_batched

    from .common import scoring_problems, timed

    cases = (
        # (precision_deg, links, jobs/link, expected batched path, label)
        (5.0, 24, 2, "grid", "A~72 typical"),
        (0.5, 24, 2, "grid", "A~720 fine-grid"),
        (5.0, 12, 3, "grid", "A~72 k=3 product grid"),
        (0.5, 8, 3, "descent", "A~720 k=3 lockstep descent"),
    )
    rows = []
    for deg, links, k, path, label in cases:
        probs = scoring_problems(num_links=links, jobs_per_link=k)
        scalar = lambda: [
            find_rotations(p, c, precision_deg=deg, backend="numpy")
            for p, c in probs
        ]
        batched = lambda: find_rotations_batched(probs, precision_deg=deg)
        batched()  # warm up (jit compile on the pallas path)
        _, us_scalar = timed(scalar)
        _, us_batch = timed(batched)
        speedup = us_scalar / us_batch

        # CI smoke assertions: the batched path must actually be taken.
        stats = BatchStats()
        find_rotations_batched(probs, precision_deg=deg, stats=stats)
        if stats.scalar_fallbacks:
            raise RuntimeError(
                f"{stats.scalar_fallbacks}/{stats.problems} problems fell "
                f"back to the scalar path at {deg:g}deg k={k}: {stats}"
            )
        taken = stats.grid_problems if path == "grid" else stats.descent_problems
        if taken != len(probs):
            raise RuntimeError(
                f"expected all {len(probs)} problems on the batched {path} "
                f"path at {deg:g}deg k={k}, got {stats}"
            )
        if k == 3 and path == "grid" and speedup <= 1.0:
            raise RuntimeError(
                f"batched k=3 grid must beat the scalar loop: "
                f"{speedup:.2f}x (scalar={us_scalar:.0f}us batched={us_batch:.0f}us)"
            )
        rows.append({
            "name": f"kernels/score_batched({links}x{k}job,{deg:g}deg)",
            "us_per_call": us_batch,
            "derived": (
                f"scalar_loop={us_scalar:.0f}us speedup={speedup:.2f}x "
                f"({label}; batched {path} path, "
                f"{stats.grid_rows + stats.descent_rows} rows in "
                f"{stats.batched_calls} calls — pallas kernel for A>=512, "
                f"vectorized numpy below)"
            ),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else ALL

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        if name == "kernels":
            rows = _kernel_bench()
        elif name == "roofline":
            from . import roofline

            rows = roofline.run()
        else:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
    print(f"# total wall: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
