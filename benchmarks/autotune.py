"""Kernel autotune driver: search, persist, and report the tuning table.

    # report how the committed table performs vs the untuned defaults
    PYTHONPATH=src python -m benchmarks.autotune

    # full measured search; write the winners to the committed table
    # location (src/repro/kernels/tune/tables/<backend>.json) and print
    # the before/after per-bucket delta report
    PYTHONPATH=src python -m benchmarks.autotune --retune

    # nightly: search into an artifact file + drift summary vs committed
    PYTHONPATH=src python -m benchmarks.autotune --retune \
        --out benchmarks/artifacts/proposed_tuning_table.json --drift

Without ``--retune`` the driver loads the committed table and re-measures
each of its entries against the untuned defaults on this machine — a
cheap health check that the committed winners still win here.

With ``--retune`` it runs the full measured grid / successive-halving
search (:mod:`repro.kernels.tune.search`): every candidate is verified
against the untuned output before it may be timed, winners only displace
defaults past a 5% hysteresis margin, and only non-default winners are
persisted (an absent entry *means* defaults).  The per-bucket report
shows default → tuned wall time and the chosen parameters.

``--drift`` compares the freshly written table against the committed one
entry by entry (added / removed / changed schedules) — the nightly CI
job uploads the proposed table as an artifact and puts this summary in
the job log; push/PR jobs never consume it, keeping gates deterministic.
The process exit code is always 0 for drift (it is informational), and
nonzero only when ``--retune`` produced no measurements at all.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.kernels import tune
from repro.kernels.tune.search import (
    make_workload,
    results_to_table,
    tune_all,
)


def _fmt_params(params: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(params.items()))


def _report_retune(results) -> None:
    print(f"{'variant/bucket':<28} {'default':>10} {'tuned':>10} "
          f"{'speedup':>8}  params")
    for r in results:
        tag = "" if r.is_default else "  <- tuned"
        print(f"{r.variant + '/' + str(r.bucket):<28} "
              f"{r.default_us:>9.0f}u {r.tuned_us:>9.0f}u "
              f"{r.speedup:>7.2f}x  {_fmt_params(dict(r.params))}{tag}")


def _check_committed(repeats: int) -> int:
    """Re-measure the committed table's entries vs defaults here."""
    table = tune.load_table()
    print(f"committed table: {table.source} (backend {table.backend}, "
          f"{len(table.entries)} entries)")
    if not table.entries:
        print("no tuned entries; nothing to measure")
        return 0
    from repro.kernels.tune.search import _timeit  # shared min-of-N timer

    print(f"{'variant/bucket':<28} {'default':>10} {'tuned':>10} "
          f"{'speedup':>8}  params")
    for key, params in sorted(table.entries.items()):
        variant, _, bucket = key.partition("/")
        run = make_workload(variant, int(bucket))
        defaults = tune.clamp_to_width(
            variant, int(bucket), tune.DEFAULTS[variant]
        )
        merged = {**defaults, **params}
        run(defaults), run(merged)  # compile both schedules
        d_us = _timeit(lambda: run(defaults), warmup=1, repeats=repeats)
        t_us = _timeit(lambda: run(merged), warmup=1, repeats=repeats)
        print(f"{key:<28} {d_us:>9.0f}u {t_us:>9.0f}u "
              f"{d_us / t_us:>7.2f}x  {_fmt_params(merged)}")
    return 0


def _drift_summary(proposed: dict, committed_path: Path) -> None:
    """Entry-by-entry diff of a proposed table vs the committed one."""
    try:
        committed = json.loads(committed_path.read_text()).get("entries", {})
    except (OSError, ValueError):
        committed = {}
    new = proposed.get("entries", {})
    added = sorted(set(new) - set(committed))
    removed = sorted(set(committed) - set(new))
    changed = sorted(
        k for k in set(new) & set(committed) if new[k] != committed[k]
    )
    print("\n== drift vs committed table ==")
    print(f"committed: {committed_path} ({len(committed)} entries); "
          f"proposed: {len(new)} entries")
    if not (added or removed or changed):
        print("no drift: the committed table matches this machine's search")
        return
    for k in added:
        print(f"  + {k}: {_fmt_params(new[k])}")
    for k in removed:
        print(f"  - {k}: {_fmt_params(committed[k])} (search now keeps "
              "defaults)")
    for k in changed:
        print(f"  ~ {k}: {_fmt_params(committed[k])} -> "
              f"{_fmt_params(new[k])}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--retune", action="store_true",
                    help="run the measured search and write a table")
    ap.add_argument("--out", type=Path, default=None,
                    help="table output path (default: the committed "
                         "per-backend file under tables/)")
    ap.add_argument("--variants", default=None,
                    help="comma-separated variant filter")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated bucket filter")
    ap.add_argument("--repeats", type=int, default=3,
                    help="min-of-N repeats in the final timing rung")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--drift", action="store_true",
                    help="after --retune, print a drift summary vs the "
                         "committed table (informational, never fails)")
    args = ap.parse_args(argv)

    if not args.retune:
        return _check_committed(args.repeats)

    variants = args.variants.split(",") if args.variants else None
    buckets = (
        [int(b) for b in args.buckets.split(",")] if args.buckets else None
    )
    results = tune_all(
        variants, buckets, repeats=args.repeats, seed=args.seed,
        progress=lambda r: print(
            f"  searched {r.variant}/{r.bucket}: "
            f"{_fmt_params(dict(r.params))} x{r.speedup:.2f}",
            flush=True,
        ),
    )
    if not results:
        print("no (variant, bucket) keys matched the filters", file=sys.stderr)
        return 1
    doc = results_to_table(results)
    out = args.out or tune.default_table_path()
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"\nwrote {out} ({len(doc['entries'])} tuned entries, backend "
          f"{doc['backend']})\n")
    _report_retune(results)
    if args.drift:
        _drift_summary(doc, tune.default_table_path())
    return 0


if __name__ == "__main__":
    sys.exit(main())
