"""Full cluster scheduling demo — the paper end-to-end.

    PYTHONPATH=src python examples/cluster_scheduling.py

Runs the ``poisson-paper`` scenario from the registry (13 paper DNN
workloads on the 24-server, 2:1-oversubscribed testbed) under Themis,
Th+CASSINI, Pollux, Po+CASSINI, Random and the Ideal reference, and prints
the comparison.  Swapping the workload is one line: pick another name from
``repro.engine.list_scenarios()`` or ``register_scenario`` your own.
"""

from repro.engine import get_scenario, list_scenarios


def main() -> None:
    scenario = get_scenario("poisson-paper")
    print(f"scenario: {scenario.name} — {scenario.description}")
    print(f"(available: {', '.join(list_scenarios())})\n")
    print(f"{'scheduler':12s} {'avg iter':>9s} {'p99 iter':>9s} "
          f"{'avg JCT':>9s} {'ECN/iter':>9s}")
    results = {}
    for name in scenario.scheduler_names():
        s = scenario.run(name).metrics.summary()
        results[name] = s
        print(f"{name:12s} {s['avg_iter_ms']:8.0f}ms {s['p99_iter_ms']:8.0f}ms "
              f"{s['avg_jct_ms']/1000:8.1f}s {s['ecn_per_iter']:9.0f}")
    mi = scenario.ideal()
    print(f"{'ideal':12s} {mi.avg_iter_ms:8.0f}ms {mi.pct_iter_ms(99):8.0f}ms")
    for a, b in (("themis", "th+cassini"), ("pollux", "po+cassini")):
        print(f"{b} vs {a}: avg {results[a]['avg_iter_ms']/results[b]['avg_iter_ms']:.2f}x, "
              f"ECN {results[a]['ecn_per_iter']/max(results[b]['ecn_per_iter'],1e-9):.1f}x fewer")


if __name__ == "__main__":
    main()
