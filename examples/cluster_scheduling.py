"""Full cluster scheduling demo — the paper end-to-end.

    PYTHONPATH=src python examples/cluster_scheduling.py

Runs the same Poisson job-arrival trace (13 paper DNN workloads on the
24-server, 2:1-oversubscribed testbed) under Themis, Th+CASSINI, Pollux,
Po+CASSINI, Random and the Ideal reference, and prints the comparison.
"""

from repro.cluster import ClusterSimulator, Topology, ideal_metrics, poisson_trace
from repro.sched import (
    CassiniAugmented,
    PolluxScheduler,
    RandomScheduler,
    ThemisScheduler,
)


def main() -> None:
    topo = Topology.paper_testbed()
    mk_jobs = lambda: poisson_trace(
        topo, load=0.95, num_jobs=16, seed=7, min_iters=150, max_iters=400,
        models=["vgg16", "vgg19", "wideresnet101", "resnet50", "bert",
                "roberta", "xlm", "gpt1", "gpt2", "gpt3", "dlrm"],
    )
    schedulers = [
        ("themis", ThemisScheduler()),
        ("th+cassini", CassiniAugmented(ThemisScheduler())),
        ("pollux", PolluxScheduler()),
        ("po+cassini", CassiniAugmented(PolluxScheduler())),
        ("random", RandomScheduler()),
    ]
    print(f"{'scheduler':12s} {'avg iter':>9s} {'p99 iter':>9s} "
          f"{'avg JCT':>9s} {'ECN/iter':>9s}")
    results = {}
    for name, sched in schedulers:
        sim = ClusterSimulator(topo, sched, epoch_ms=300_000, compute_jitter=0.005)
        m = sim.run(mk_jobs(), horizon_ms=7_200_000)
        s = m.summary()
        results[name] = s
        print(f"{name:12s} {s['avg_iter_ms']:8.0f}ms {s['p99_iter_ms']:8.0f}ms "
              f"{s['avg_jct_ms']/1000:8.1f}s {s['ecn_per_iter']:9.0f}")
    mi = ideal_metrics(topo, mk_jobs())
    print(f"{'ideal':12s} {mi.avg_iter_ms:8.0f}ms {mi.pct_iter_ms(99):8.0f}ms")
    for a, b in (("themis", "th+cassini"), ("pollux", "po+cassini")):
        print(f"{b} vs {a}: avg {results[a]['avg_iter_ms']/results[b]['avg_iter_ms']:.2f}x, "
              f"ECN {results[a]['ecn_per_iter']/max(results[b]['ecn_per_iter'],1e-9):.1f}x fewer")


if __name__ == "__main__":
    main()
