"""End-to-end training driver: train a smollm-family model on the synthetic
Markov LM task, crash it mid-run, and watch it resume from the checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]

``--full`` trains the real 135M-parameter smollm config (slow on CPU);
the default trains a ~3M reduced sibling in about a minute.
"""

import argparse
import shutil
import tempfile

from repro.configs import get_config
from repro.models.api import build_model
from repro.train.data import SyntheticLM
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced(d_model=192, num_layers=4, d_ff=512, vocab=2048,
                          num_heads=4, num_kv_heads=2, remat="none")
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    ckpt = tempfile.mkdtemp(prefix="repro_train_lm_")
    print(f"arch={cfg.name} checkpoints -> {ckpt}")

    # phase 1: train, but a node "fails" two-thirds through
    fail_at = args.steps * 2 // 3
    t1 = Trainer(model, data, TrainerConfig(
        steps=args.steps, ckpt_every=50, ckpt_dir=ckpt, log_every=25,
        fail_at_step=fail_at))
    try:
        t1.run()
    except RuntimeError as e:
        print(f"!! {e} — restarting from the latest committed checkpoint")

    # phase 2: restart; the trainer restores and continues
    t2 = Trainer(model, data, TrainerConfig(
        steps=args.steps, ckpt_every=50, ckpt_dir=ckpt, log_every=25))
    res = t2.run()
    print(f"resumed from step {res.restored_from}, ran {res.steps_run} more steps")
    print("losses:", " ".join(f"{l:.3f}" for l in res.losses))
    verdict = "improved" if res.losses[-1] < res.losses[0] else "NOT improved"
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} ({verdict})")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
