"""Quickstart: CASSINI's core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. describe two jobs' periodic communication patterns,
2. score their compatibility on a 50 Gbps link and get the time-shift,
3. build a cluster-level affinity graph and compute unique shifts,
4. let the pluggable module pick the best of two placements.
"""

from repro.core import (
    AffinityGraph,
    CassiniModule,
    CommPattern,
    Phase,
    PlacementCandidate,
    find_rotations,
)

# 1) two data-parallel jobs: 320 ms iterations, ~45 % communication duty
vgg16 = CommPattern(320.0, (Phase(170.0, 150.0, 45.0),), name="vgg16")
wrn = CommPattern(320.0, (Phase(239.0, 81.0, 40.0),), name="wideresnet101")

# 2) link-level compatibility (paper Table 1)
res = find_rotations([wrn, vgg16], capacity_gbps=50.0)
print(f"compatibility score : {res.score:.2f}")
print(f"time-shifts (ms)    : {dict(zip(['wrn', 'vgg16'], res.shifts_ms))}")
print(f"paced periods (ms)  : {res.paced_periods_ms}")

# 3) cluster level: j2 shares l1 with j1 and l2 with j3 (paper Fig. 5/6)
g = AffinityGraph()
g.add_edge("j1", "l1", res.shifts_ms[0], wrn.iter_time_ms)
g.add_edge("j2", "l1", res.shifts_ms[1], vgg16.iter_time_ms)
g.add_edge("j2", "l2", 40.0, vgg16.iter_time_ms)
g.add_edge("j3", "l2", 90.0, 240.0)
shifts = g.bfs_time_shifts(seed=0)
print(f"unique cluster-level shifts: { {k: round(v, 1) for k, v in shifts.items()} }")
print(f"Theorem 1 holds     : {g.check_theorem1(shifts)}")

# 4) pluggable module: pick the best placement candidate (Algorithm 2)
patterns = {"a": wrn, "b": vgg16, "c": CommPattern(200.0, (Phase(40.0, 150.0, 45.0),), "heavy")}
caps = {"l1": 50.0}
good = PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"], "c": []})
bad = PlacementCandidate(job_links={"a": ["l1"], "c": ["l1"], "b": []})
decision = CassiniModule().decide([bad, good], patterns, caps)
winner = "good" if decision.top_placement is good else "bad"
print(f"module chose the {winner} placement (score {decision.score:.2f}) "
      f"with shifts { {k: round(v, 1) for k, v in decision.time_shifts_ms.items()} }")
