"""Quickstart: CASSINI's core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. describe two jobs' periodic communication patterns,
2. score their compatibility on a 50 Gbps link and get the time-shift,
3. build a cluster-level affinity graph and compute unique shifts,
4. let the pluggable module pick the best of two placements,
5. run the full typed scheduling pipeline on a small cluster.
"""

from repro.core import (
    AffinityGraph,
    CassiniModule,
    CommPattern,
    Phase,
    PlacementCandidate,
    find_rotations,
)

# 1) two data-parallel jobs: 320 ms iterations, ~45 % communication duty
vgg16 = CommPattern(320.0, (Phase(170.0, 150.0, 45.0),), name="vgg16")
wrn = CommPattern(320.0, (Phase(239.0, 81.0, 40.0),), name="wideresnet101")

# 2) link-level compatibility (paper Table 1)
res = find_rotations([wrn, vgg16], capacity_gbps=50.0)
print(f"compatibility score : {res.score:.2f}")
print(f"time-shifts (ms)    : {dict(zip(['wrn', 'vgg16'], res.shifts_ms))}")
print(f"paced periods (ms)  : {res.paced_periods_ms}")

# 3) cluster level: j2 shares l1 with j1 and l2 with j3 (paper Fig. 5/6)
g = AffinityGraph()
g.add_edge("j1", "l1", res.shifts_ms[0], wrn.iter_time_ms)
g.add_edge("j2", "l1", res.shifts_ms[1], vgg16.iter_time_ms)
g.add_edge("j2", "l2", 40.0, vgg16.iter_time_ms)
g.add_edge("j3", "l2", 90.0, 240.0)
shifts = g.bfs_time_shifts(seed=0)
print(f"unique cluster-level shifts: { {k: round(v, 1) for k, v in shifts.items()} }")
print(f"Theorem 1 holds     : {g.check_theorem1(shifts)}")

# 4) pluggable module: pick the best placement candidate (Algorithm 2)
patterns = {"a": wrn, "b": vgg16, "c": CommPattern(200.0, (Phase(40.0, 150.0, 45.0),), "heavy")}
caps = {"l1": 50.0}
good = PlacementCandidate(job_links={"a": ["l1"], "b": ["l1"], "c": []})
bad = PlacementCandidate(job_links={"a": ["l1"], "c": ["l1"], "b": []})
decision = CassiniModule().decide([bad, good], patterns, caps)
winner = "good" if decision.top_placement is good else "bad"
print(f"module chose the {winner} placement (score {decision.score:.2f}) "
      f"with shifts { {k: round(v, 1) for k, v in decision.time_shifts_ms.items()} }")

# 5) the typed pipeline: Allocate → Propose → Score → Align on a cluster.
#    Two VGG19 jobs pinned onto the same rack-pair uplink (the Fig. 2
#    scenario): the Decision carries a typed AlignmentPlan (no meta dicts);
#    repro.engine.get_scenario offers full experiments by name.
from repro.cluster import Topology
from repro.cluster.job import Job, JobState
from repro.engine import SchedulingPipeline, list_scenarios
from repro.sched.base import ClusterState
from repro.sched.fixed import FixedPlacementScheduler

jobs = [Job(job_id=f"j{i}", model="vgg19", num_workers=2, duration_iters=100,
            batch_per_gpu=1400) for i in range(2)]
for j in jobs:
    j.state = JobState.RUNNING
state = ClusterState(topology=Topology.paper_testbed(), now_ms=0.0,
                     running=jobs, pending=[])
pinned = FixedPlacementScheduler({"j0": (0, 6), "j1": (1, 7)})
pipe = SchedulingPipeline.cassini(pinned, num_candidates=1)
d = pipe.schedule(state)
print(f"pipeline stages      : {[s.name for s in pipe.stages]}")
print(f"pipeline decision    : score={d.compat_score:.2f} "
      f"shifts={ {k: round(v, 1) for k, v in d.time_shifts_ms.items()} } "
      f"paced={ {k: round(v) for k, v in d.plan.paced_periods_ms.items()} } "
      f"hold={ {k: d.plan.align_ok(k) for k in d.placements} }")
print(f"registered scenarios : {', '.join(list_scenarios())}")
