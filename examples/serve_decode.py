"""Batched autoregressive serving demo: prefill a batch of prompts, then
greedy-decode continuation tokens with the KV cache / SSM state.

    PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-1.3b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models.api import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    print(f"arch={cfg.name} batch={b} prompt={s} new={args.new_tokens}")

    state = model.init_decode_state(b, s + args.new_tokens)
    step = jax.jit(model.serve_step)

    # prefill via the decode path (token-by-token teacher forcing keeps the
    # example family-agnostic; the prefill_32k path is exercised by dryrun)
    t0 = time.time()
    logits = None
    for t in range(s):
        logits, state = step(params, prompts[:, t:t + 1], state)
    print(f"prefill: {s} steps in {time.time()-t0:.2f}s")

    # greedy decode
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new_tokens} tokens/seq in {dt:.2f}s "
          f"({b * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
